"""Multi-relation maintenance throughput -> BENCH_nary_stream.json.

The §5.4 claim, measured: maintaining 4-clique through the ternary ``tri``
relation (3 ternary atoms, composite-key regions) vs through the binary
edge relation (6 binary atoms).  Per scale |E| ∈ {1e4, 1e5}:

- an UNTIMED feeder session runs the standing triangle query over the edge
  stream and records every epoch's signed triangle delta — the tri
  relation's update batches;
- the TIMED edge side is a session holding only 4-clique (6 edge atoms,
  6 delta plans per epoch) driven by the edge batches;
- the TIMED tri side is a session holding only 4-clique-tri (3 tri atoms,
  3 delta plans per epoch over n-ary composite-key regions) driven by the
  recorded tri deltas.

Every epoch both sides' signed output deltas are checked BIT-EXACT against
each other (two completely different plans agreeing is the differential
oracle); the small scale additionally verifies the maintained net against
full recomputation.

Both timed sessions walk the AOT prewarm ladder (``session.prewarm``,
DESIGN.md §8) before their loops — cold time is split out — and the warm
latency tail is gated: p99/p50 ≤ 5× with zero jit rebuilds after warmup.

Run via ``python -m benchmarks.run --only nary_stream`` (or directly).
"""
import json
import os
import time

import numpy as np

from benchmarks.common import row

OUT_PATH = os.path.join(os.path.dirname(__file__), "results",
                        "BENCH_nary_stream.json")

SCALES = [10_000, 100_000]
BATCH = 64
WARMUP, EPOCHS = 3, 12
BPRIME, OUT_CAP = 1024, 1 << 18


def _canon(t, w):
    from repro.api import canon_signed
    return canon_signed(t, w)


def _graph(ne: int):
    from repro.data.synthetic import uniform_graph
    nv = max(ne // 8, 64)
    return nv, uniform_graph(nv, int(ne * 1.08), seed=ne % 89)


def _feeder(nv, edges, n_epochs):
    """Untimed pass: evolve the edge stream, record every epoch's edge
    batch AND the triangle query's signed delta (the tri batches)."""
    from repro.api import GraphSession
    from repro.data.synthetic import EdgeUpdateStream
    sess = GraphSession(edges, local=True, batch=BPRIME,
                        out_capacity=OUT_CAP, update_batch=BATCH)
    tri = sess.register("triangle")
    tri0, _ = tri.enumerate()
    stream = EdgeUpdateStream(nv, BATCH, seed=5)
    live = sess.edges
    out = []
    for step in range(n_epochs):
        upd, w = stream.batch_at(step, live=live)
        res = sess.update(upd, w)
        live = res.advance(live)
        d = res.deltas["triangle"]
        t_upd = d.tuples if d.tuples is not None else \
            np.zeros((0, 3), np.int32)
        t_w = d.weights if d.weights is not None else np.zeros(0, np.int32)
        out.append(((upd, w), (t_upd, t_w)))
    return tri0, out


def _drive(session, name, batches):
    """Timed loop: prewarm (cold, reported separately), then one update per
    epoch with per-epoch latency, compile events, and deltas."""
    t0 = time.time()
    session.prewarm(horizon=len(batches) * BATCH)
    prewarm_s = time.time() - t0
    lat, deltas, compiles = [], [], []
    for batch in batches:
        t0 = time.time()
        res = session.update(batch)
        lat.append(time.time() - t0)
        deltas.append(res.deltas[name])
        compiles.append(res.compile_events)
    warm = np.asarray(lat[WARMUP:]) * 1e3
    pct = {k: round(float(np.percentile(warm, q)), 3)
           for k, q in (("p50", 50), ("p95", 95), ("p99", 99))}
    pct["max"] = round(float(warm.max()), 3)
    tail = {"cold_prewarm_ms": round(prewarm_s * 1e3, 1),
            "prewarm_compiles": session.stats.prewarm_compiles,
            "warm_compiles": int(sum(compiles[WARMUP:])),
            "epoch_compiles": compiles, **pct,
            "p99_p50_ratio": round(pct["p99"] / max(pct["p50"], 1e-9), 3)}
    return pct["p50"], lat, deltas, tail


def main():
    from repro.api import GraphSession, oracle_count
    rec = {"bench": "nary_stream", "batch_size": BATCH, "warmup": WARMUP,
           "epochs": EPOCHS, "scales": {}}
    all_exact = True
    for ne in SCALES:
        nv, edges = _graph(ne)
        tri0, epochs = _feeder(nv, edges, WARMUP + EPOCHS)

        edge_sess = GraphSession(edges, local=True, batch=BPRIME,
                                 out_capacity=OUT_CAP, update_batch=BATCH)
        edge_sess.register("4-clique")
        tri_sess = GraphSession({"tri": tri0}, local=True, batch=BPRIME,
                                out_capacity=OUT_CAP, update_batch=BATCH)
        tri_sess.register("4-clique-tri")

        e_ms, e_lat, e_deltas, e_tail = _drive(
            edge_sess, "4-clique", [dict(edge=b[0]) for b in epochs])
        t_ms, t_lat, t_deltas, t_tail = _drive(
            tri_sess, "4-clique-tri", [dict(tri=b[1]) for b in epochs])

        exact = all(
            _canon(a.tuples, a.weights) == _canon(b.tuples, b.weights)
            for a, b in zip(e_deltas, t_deltas))
        if ne == min(SCALES):  # recompute oracle at the small scale
            net = sum(d.count_delta for d in e_deltas)
            ref = oracle_count("4-clique", edge_sess.edges) - \
                oracle_count("4-clique", edges)
            exact = exact and net == ref == sum(
                d.count_delta for d in t_deltas)
        all_exact = all_exact and exact
        entry = {
            "edges": int(edges.shape[0]), "num_vertices": nv,
            "tri_tuples": int(tri0.shape[0]),
            "edge_plan_warm_ms": round(e_ms, 3),
            "tri_plan_warm_ms": round(t_ms, 3),
            "edge_plan_epochs_per_s": round(1e3 / max(e_ms, 1e-9), 2),
            "tri_plan_epochs_per_s": round(1e3 / max(t_ms, 1e-9), 2),
            "tri_over_edge": round(t_ms / max(e_ms, 1e-9), 3),
            "edge_epoch_ms": [round(t * 1e3, 2) for t in e_lat],
            "tri_epoch_ms": [round(t * 1e3, 2) for t in t_lat],
            "edge_plan_latency": e_tail,
            "tri_plan_latency": t_tail,
            "exact": bool(exact),
        }
        rec["scales"][str(ne)] = entry
        row("nary_stream", f"edge_plan_E{ne}", e_ms / 1e3,
            f"|E|={edges.shape[0]} warm_ms={e_ms:.1f} exact={exact} "
            f"p99/p50={e_tail['p99_p50_ratio']}x "
            f"warm_compiles={e_tail['warm_compiles']}")
        row("nary_stream", f"tri_plan_E{ne}", t_ms / 1e3,
            f"|tri|={tri0.shape[0]} warm_ms={t_ms:.1f} "
            f"ratio={t_ms / max(e_ms, 1e-9):.2f}x "
            f"p99/p50={t_tail['p99_p50_ratio']}x "
            f"warm_compiles={t_tail['warm_compiles']}")
    rec["all_exact"] = bool(all_exact)
    tails = [rec["scales"][str(ne)][k] for ne in SCALES
             for k in ("edge_plan_latency", "tri_plan_latency")]
    rec["p99_p50_max"] = max(t["p99_p50_ratio"] for t in tails)
    rec["warm_compiles"] = sum(t["warm_compiles"] for t in tails)
    rec["tail_flat"] = bool(rec["p99_p50_max"] <= 5.0
                            and rec["warm_compiles"] == 0)
    os.makedirs(os.path.dirname(OUT_PATH), exist_ok=True)
    with open(OUT_PATH, "w") as f:
        json.dump(rec, f, indent=2)
    row("nary_stream", "tail_flat", 0.0,
        f"p99/p50<={rec['p99_p50_max']}x "
        f"warm_compiles={rec['warm_compiles']} (flat: {rec['tail_flat']})")
    row("nary_stream", "json", 0.0, OUT_PATH)
    if not all_exact:
        raise SystemExit("nary_stream: plan parity check FAILED")


if __name__ == "__main__":
    main()
