"""Multi-relation maintenance throughput -> BENCH_nary_stream.json.

The §5.4 claim, measured: maintaining 4-clique through the ternary ``tri``
relation (3 ternary atoms, composite-key regions) vs through the binary
edge relation (6 binary atoms).  Per scale |E| ∈ {1e4, 1e5}:

- an UNTIMED feeder session runs the standing triangle query over the edge
  stream and records every epoch's signed triangle delta — the tri
  relation's update batches;
- the TIMED edge side is a session holding only 4-clique (6 edge atoms,
  6 delta plans per epoch) driven by the edge batches;
- the TIMED tri side is a session holding only 4-clique-tri (3 tri atoms,
  3 delta plans per epoch over n-ary composite-key regions) driven by the
  recorded tri deltas.

Every epoch both sides' signed output deltas are checked BIT-EXACT against
each other (two completely different plans agreeing is the differential
oracle); the small scale additionally verifies the maintained net against
full recomputation.

Run via ``python -m benchmarks.run --only nary_stream`` (or directly).
"""
import json
import os
import time

import numpy as np

from benchmarks.common import row

OUT_PATH = os.path.join(os.path.dirname(__file__), "results",
                        "BENCH_nary_stream.json")

SCALES = [10_000, 100_000]
BATCH = 64
WARMUP, EPOCHS = 3, 12
BPRIME, OUT_CAP = 1024, 1 << 18


def _canon(t, w):
    from repro.api import canon_signed
    return canon_signed(t, w)


def _graph(ne: int):
    from repro.data.synthetic import uniform_graph
    nv = max(ne // 8, 64)
    return nv, uniform_graph(nv, int(ne * 1.08), seed=ne % 89)


def _feeder(nv, edges, n_epochs):
    """Untimed pass: evolve the edge stream, record every epoch's edge
    batch AND the triangle query's signed delta (the tri batches)."""
    from repro.api import GraphSession
    from repro.data.synthetic import EdgeUpdateStream
    sess = GraphSession(edges, local=True, batch=BPRIME,
                        out_capacity=OUT_CAP, update_batch=BATCH)
    tri = sess.register("triangle")
    tri0, _ = tri.enumerate()
    stream = EdgeUpdateStream(nv, BATCH, seed=5)
    live = sess.edges
    out = []
    for step in range(n_epochs):
        upd, w = stream.batch_at(step, live=live)
        res = sess.update(upd, w)
        live = res.advance(live)
        d = res.deltas["triangle"]
        t_upd = d.tuples if d.tuples is not None else \
            np.zeros((0, 3), np.int32)
        t_w = d.weights if d.weights is not None else np.zeros(0, np.int32)
        out.append(((upd, w), (t_upd, t_w)))
    return tri0, out


def _drive(session, name, batches):
    """Timed loop: one update per epoch, per-epoch latency + deltas."""
    lat, deltas = [], []
    for batch in batches:
        t0 = time.time()
        res = session.update(batch)
        lat.append(time.time() - t0)
        deltas.append(res.deltas[name])
    warm = sorted(lat[WARMUP:])
    return warm[len(warm) // 2] * 1e3, lat, deltas


def main():
    from repro.api import GraphSession, oracle_count
    rec = {"bench": "nary_stream", "batch_size": BATCH, "warmup": WARMUP,
           "epochs": EPOCHS, "scales": {}}
    all_exact = True
    for ne in SCALES:
        nv, edges = _graph(ne)
        tri0, epochs = _feeder(nv, edges, WARMUP + EPOCHS)

        edge_sess = GraphSession(edges, local=True, batch=BPRIME,
                                 out_capacity=OUT_CAP, update_batch=BATCH)
        edge_sess.register("4-clique")
        tri_sess = GraphSession({"tri": tri0}, local=True, batch=BPRIME,
                                out_capacity=OUT_CAP, update_batch=BATCH)
        tri_sess.register("4-clique-tri")

        e_ms, e_lat, e_deltas = _drive(
            edge_sess, "4-clique", [dict(edge=b[0]) for b in epochs])
        t_ms, t_lat, t_deltas = _drive(
            tri_sess, "4-clique-tri", [dict(tri=b[1]) for b in epochs])

        exact = all(
            _canon(a.tuples, a.weights) == _canon(b.tuples, b.weights)
            for a, b in zip(e_deltas, t_deltas))
        if ne == min(SCALES):  # recompute oracle at the small scale
            net = sum(d.count_delta for d in e_deltas)
            ref = oracle_count("4-clique", edge_sess.edges) - \
                oracle_count("4-clique", edges)
            exact = exact and net == ref == sum(
                d.count_delta for d in t_deltas)
        all_exact = all_exact and exact
        entry = {
            "edges": int(edges.shape[0]), "num_vertices": nv,
            "tri_tuples": int(tri0.shape[0]),
            "edge_plan_warm_ms": round(e_ms, 3),
            "tri_plan_warm_ms": round(t_ms, 3),
            "edge_plan_epochs_per_s": round(1e3 / max(e_ms, 1e-9), 2),
            "tri_plan_epochs_per_s": round(1e3 / max(t_ms, 1e-9), 2),
            "tri_over_edge": round(t_ms / max(e_ms, 1e-9), 3),
            "edge_epoch_ms": [round(t * 1e3, 2) for t in e_lat],
            "tri_epoch_ms": [round(t * 1e3, 2) for t in t_lat],
            "exact": bool(exact),
        }
        rec["scales"][str(ne)] = entry
        row("nary_stream", f"edge_plan_E{ne}", e_ms / 1e3,
            f"|E|={edges.shape[0]} warm_ms={e_ms:.1f} exact={exact}")
        row("nary_stream", f"tri_plan_E{ne}", t_ms / 1e3,
            f"|tri|={tri0.shape[0]} warm_ms={t_ms:.1f} "
            f"ratio={t_ms / max(e_ms, 1e-9):.2f}x")
    rec["all_exact"] = bool(all_exact)
    os.makedirs(os.path.dirname(OUT_PATH), exist_ok=True)
    with open(OUT_PATH, "w") as f:
        json.dump(rec, f, indent=2)
    row("nary_stream", "json", 0.0, OUT_PATH)
    if not all_exact:
        raise SystemExit("nary_stream: plan parity check FAILED")


if __name__ == "__main__":
    main()
