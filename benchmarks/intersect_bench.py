"""Intersect/extension-pipeline microbenchmarks -> BENCH_intersect.json.

Tracks the perf trajectory of the PR's fused extension-step pipeline across
three measurements (interpret mode off-TPU; numbers are comparable per-host):

  member    — membership queries/sec: pure-jnp ref vs the vectorized
              two-level Pallas kernel.
  regions   — a 5-region VersionedIndex probe: per-region jnp reduction vs
              the single fused multi-region launch, plus the pallas_call
              counts proving the >= 1 launch reduction per probe.
  bigjoin   — end-to-end dataflow steps/sec for the triangle query:
              jnp stage sequence vs the fused extend-step kernel path.

Run via ``python -m benchmarks.run --only intersect`` (or directly).  The
JSON lands in benchmarks/results/BENCH_intersect.json so successive PRs can
diff queries/sec and steps/sec machine-readably.
"""
import json
import os

import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, timeit

OUT_PATH = os.path.join(os.path.dirname(__file__), "results",
                        "BENCH_intersect.json")


def _bench_member(rec):
    from repro.kernels.intersect.ops import member as member_kernel
    from repro.kernels.intersect.ref import member_ref
    rng = np.random.default_rng(0)
    n, B = 1 << 15, 4096
    k = np.sort(rng.integers(0, 1 << 20, n)).astype(np.int32)
    v = rng.integers(0, 1 << 10, n).astype(np.int32)
    kv = np.stack([k.astype(np.int64), v.astype(np.int64)], 1)
    kv = kv[np.lexsort((kv[:, 1], kv[:, 0]))]
    k, v = kv[:, 0].astype(np.int32), kv[:, 1].astype(np.int32)
    qk = rng.integers(0, 1 << 20, B).astype(np.int32)
    qv = rng.integers(0, 1 << 10, B).astype(np.int32)
    args = (jnp.asarray(k), jnp.asarray(v), jnp.asarray(np.int32(n)),
            jnp.asarray(qk), jnp.asarray(qv))

    t_ref, out_ref = timeit(lambda: np.asarray(member_ref(*args)))
    t_ker, out_ker = timeit(lambda: np.asarray(member_kernel(*args)))
    parity = bool((out_ref == out_ker).all())
    rec["member"] = {
        "index_entries": n, "batch": B,
        "ref_qps": B / t_ref, "kernel_qps": B / t_ker,
        "bit_exact": parity,
    }
    row("intersect", "member_ref", t_ref, f"{B / t_ref:.0f} q/s")
    row("intersect", "member_kernel", t_ker,
        f"{B / t_ker:.0f} q/s parity={parity}")
    assert parity, "kernel membership diverged from ref.py"


def _bench_regions(rec):
    from repro.core.csr import build_index
    from repro.core.dataflow_index import VersionedIndex
    from repro.kernels import count_pallas_calls
    rng = np.random.default_rng(1)

    def reg(n):
        return build_index(rng.integers(0, 500, (n, 2)).astype(np.int32),
                           (0,), 1)

    idx = VersionedIndex((reg(4000), reg(300), reg(150)),
                         (reg(150), reg(100)))
    B = 4096
    qk = jnp.asarray(rng.integers(0, 500, B).astype(np.int32))
    qv = jnp.asarray(rng.integers(0, 500, B).astype(np.int32))

    t_jnp, m_jnp = timeit(
        lambda: np.asarray(idx.member(qk, qv, use_kernel=False)))
    t_fus, m_fus = timeit(
        lambda: np.asarray(idx.member(qk, qv, use_kernel=True)))
    launches = count_pallas_calls(
        lambda a, b: idx.member(a, b, use_kernel=True), qk, qv)
    R = len(idx.pos) + len(idx.neg)
    parity = bool((m_jnp == m_fus).all())
    rec["regions"] = {
        "num_regions": R, "batch": B,
        "jnp_qps": B / t_jnp, "fused_qps": B / t_fus,
        "fused_pallas_calls": launches,
        "launches_saved_vs_per_region": R - launches,
        "bit_exact": parity,
    }
    row("intersect", "member_5regions_jnp", t_jnp, f"{B / t_jnp:.0f} q/s")
    row("intersect", "member_5regions_fused", t_fus,
        f"{B / t_fus:.0f} q/s {launches} launch")
    assert launches == 1 and R - launches >= 1
    assert parity


def _bench_bigjoin(rec):
    from repro.core import query as Q
    from repro.core.bigjoin import (BigJoinConfig, build_indices,
                                    run_bigjoin, seed_tuples_for)
    from repro.core.plan import make_plan
    from repro.data.synthetic import rmat_graph
    e = rmat_graph(12, 6, seed=5)
    q = Q.triangle()
    plan = make_plan(q)
    rels = {Q.EDGE: e}
    idx = build_indices(plan, rels)
    seed = seed_tuples_for(plan, rels)
    rec["bigjoin"] = {}
    for name, use_kernel in (("jnp", False), ("kernel", True)):
        cfg = BigJoinConfig(batch=4096, seed_chunk=4096, mode="count",
                            use_kernel=use_kernel)
        t, res = timeit(lambda: run_bigjoin(plan, idx, seed, cfg=cfg),
                        repeat=3)
        rec["bigjoin"][name] = {
            "steps": res.steps, "steps_per_sec": res.steps / t,
            "proposals_per_sec": res.proposals / t, "count": res.count,
        }
        row("intersect", f"bigjoin_steps_{name}", t,
            f"{res.steps / t:.1f} steps/s")
    assert rec["bigjoin"]["jnp"]["count"] == \
        rec["bigjoin"]["kernel"]["count"]


def main():
    rec = {"bench": "intersect", "interpret_mode": True}
    import jax
    rec["backend"] = jax.default_backend()
    rec["interpret_mode"] = jax.default_backend() != "tpu"
    _bench_member(rec)
    _bench_regions(rec)
    _bench_bigjoin(rec)
    os.makedirs(os.path.dirname(OUT_PATH), exist_ok=True)
    with open(OUT_PATH, "w") as f:
        json.dump(rec, f, indent=2)
    row("intersect", "json", 0.0, OUT_PATH)


if __name__ == "__main__":
    main()
