"""Fig 4 (COST): optimized single-thread triangle counting vs BiGJoin vs
Delta-BiGJoin.  The paper's COST metric = cores a parallel system needs to
beat one good thread; here we report the single-core ratio directly (this
container has one core, so ratio < ~#cores is the 'small COST' signal)."""
import numpy as np

from benchmarks.common import row, timeit
from repro.core import query as Q
from repro.core.bigjoin import (BigJoinConfig, build_indices, run_bigjoin,
                                seed_tuples_for)
from repro.core.delta import DeltaBigJoin
from repro.core.generic_join import fast_triangle_count
from repro.core.plan import make_plan
from repro.data.synthetic import rmat_graph


def main(scale=12, edge_factor=8):
    edges = rmat_graph(scale, edge_factor, seed=0)
    from repro.core.csr import Graph
    g = Graph.from_edges(edges).degree_relabel()
    q = Q.triangle(symmetric=True)
    plan = make_plan(q)
    rels = {Q.EDGE: g.edges}

    t_single, n_single = timeit(fast_triangle_count, g.edges, repeat=3)
    row("fig4_cost", "single_thread", t_single, n_single)

    cfg = BigJoinConfig(batch=8192, seed_chunk=8192, mode="count")
    idx = build_indices(plan, rels)
    seed = seed_tuples_for(plan, rels)
    t_big, res = timeit(
        lambda: run_bigjoin(plan, idx, seed, cfg=cfg), repeat=3)
    assert res.count == n_single, (res.count, n_single)
    row("fig4_cost", "bigjoin_w1", t_big,
        f"cost_ratio={t_big / t_single:.2f}")

    # Delta-BiGJoin finding all triangles by streaming the edges in
    def delta_all():
        eng = DeltaBigJoin(q, g.edges[:0],
                           cfg=BigJoinConfig(batch=8192, seed_chunk=8192,
                                             mode="count", out_capacity=1))
        total = 0
        B = max(g.num_edges // 4, 1)
        for lo in range(0, g.num_edges, B):
            total += eng.apply(g.edges[lo:lo + B]).count_delta
        return total

    t_delta, n_delta = timeit(delta_all, repeat=1)
    assert n_delta == n_single
    row("fig4_cost", "delta_bigjoin_w1", t_delta,
        f"cost_ratio={t_delta / t_single:.2f}")


if __name__ == "__main__":
    main()
