"""Shared-session vs independent-engine throughput -> BENCH_multi_query.json.

The facade's economic claim: N standing queries on ONE GraphSession share
the multi-version index regions and pay one normalize/commit per epoch,
where N independent DeltaBigJoin engines pay N of each.  For N in {1, 2, 4}
this benchmark drives the same adversarial update stream through both
arrangements (host-local, in-process), checks the signed per-query output
deltas are bit-exact between them every epoch, and records warm epoch
throughput plus the store's commit accounting.

Run via ``python -m benchmarks.run --only multi_query`` (or directly).
"""
import json
import os
import time

import numpy as np

from benchmarks.common import row

OUT_PATH = os.path.join(os.path.dirname(__file__), "results",
                        "BENCH_multi_query.json")

QUERIES = ("triangle", "diamond", "4-clique", "house")
NV, NE = 80, 700
EPOCHS, BATCH_SIZE = 8, 48
BPRIME, OUT_CAP = 512, 1 << 16
WARMUP = 2


def _canon(t, w):
    from repro.core.delta import canon_signed
    return canon_signed(t, w)


def _batches(live0):
    """The same deterministic update sequence for both arrangements (one
    tracker store replays the live-set evolution the engines will see)."""
    from repro.core.delta import RegionStore
    from repro.data.synthetic import EdgeUpdateStream
    stream = EdgeUpdateStream(NV, BATCH_SIZE, seed=11)
    # host store: pure untimed bookkeeping, no fold compilation
    tracker = RegionStore(live0, device_resident=False)
    out = []
    for step in range(EPOCHS):
        upd, w = stream.batch_at(step, live=tracker.edges)
        ins, dels = tracker.normalize(upd, w)
        if ins.size or dels.size:
            tracker.begin_epoch(ins, dels)
            tracker.commit(ins, dels)
        out.append((upd, w))
    return out


def _fresh_compile_cache():
    """Both arrangements share plan+config and hence jit-cache entries;
    whoever runs FIRST absorbs every compile.  Clear between timed runs so
    each pays its own (identical) compilation at the same epochs."""
    from repro.core.bigjoin import _compiled_fns
    _compiled_fns.cache_clear()


def _run_shared(names, edges, batches):
    from repro.api import GraphSession
    _fresh_compile_cache()
    sess = GraphSession(edges, local=True, batch=BPRIME,
                        out_capacity=OUT_CAP, update_batch=BATCH_SIZE)
    handles = [sess.register(n) for n in names]
    times, outs = [], []
    for upd, w in batches:
        t0 = time.time()
        res = sess.update(upd, w)
        times.append(time.time() - t0)
        outs.append({h.name: _canon(res.deltas[h.name].tuples,
                                    res.deltas[h.name].weights)
                     for h in handles})
    return times, outs, sess.stats


def _run_independent(names, edges, batches):
    from repro.api import query_by_name
    from repro.core.bigjoin import BigJoinConfig
    from repro.core.delta import DeltaBigJoin
    _fresh_compile_cache()
    cfg = BigJoinConfig(batch=BPRIME, seed_chunk=BPRIME, mode="collect",
                        out_capacity=OUT_CAP)
    engines = {n: DeltaBigJoin(query_by_name(n), edges, cfg=cfg)
               for n in names}
    times, outs = [], []
    for upd, w in batches:
        t0 = time.time()
        per = {}
        for n, eng in engines.items():
            res = eng.apply(upd, w)
            per[n] = _canon(res.tuples, res.weights)
        times.append(time.time() - t0)
        outs.append(per)
    total_commits = sum(e.store.stats.commit_calls
                        for e in engines.values())
    return times, outs, total_commits


def main():
    from repro.data.synthetic import uniform_graph
    edges = uniform_graph(NV, NE, 5)
    batches = _batches(edges)
    rec = {"bench": "multi_query", "nv": NV, "ne": NE, "epochs": EPOCHS,
           "batch_size": BATCH_SIZE, "bprime": BPRIME, "configs": {}}
    for n in (1, 2, 4):
        names = QUERIES[:n]
        st, so, stats = _run_shared(names, edges, batches)
        it, io, ind_commits = _run_independent(names, edges, batches)
        exact = all(a == b for a, b in zip(so, io))
        assert exact, f"shared vs independent outputs diverged at n={n}"
        warm_s = st[WARMUP:] or st
        warm_i = it[WARMUP:] or it
        # median epoch time: robust to the occasional mid-run recompile
        # when a region capacity crosses a pow2 boundary
        eps_s = 1.0 / max(float(np.median(warm_s)), 1e-9)
        eps_i = 1.0 / max(float(np.median(warm_i)), 1e-9)
        rec["configs"][str(n)] = {
            "queries": list(names),
            "shared_warm_epochs_per_s": round(eps_s, 2),
            "independent_warm_epochs_per_s": round(eps_i, 2),
            "speedup": round(eps_s / max(eps_i, 1e-9), 2),
            "shared_commits": stats.commit_calls,
            "independent_commits": ind_commits,
            "exact": exact,
            "shared_epoch_s": [round(t, 4) for t in st],
            "independent_epoch_s": [round(t, 4) for t in it],
        }
        row("multi_query", f"n{n}", sum(warm_s) / max(len(warm_s), 1),
            f"shared {eps_s:.2f} eps vs indep {eps_i:.2f} eps "
            f"({stats.commit_calls} vs {ind_commits} commits) "
            f"exact={exact}")
    os.makedirs(os.path.dirname(OUT_PATH), exist_ok=True)
    with open(OUT_PATH, "w") as f:
        json.dump(rec, f, indent=2)
    row("multi_query", "json", 0.0, OUT_PATH)


if __name__ == "__main__":
    main()
