"""Hillclimb cell #3 (wcoj triangle_static): B' sweep on the production
mesh, for BOTH execution paths — the jnp stage sequence and the fused
Pallas extension-step kernel (``use_kernel``).  The join's per-round
roofline terms are fixed costs amortized over w*B' proposals; throughput =
w*B' / max(term).  The sweep records the crossover batch size: the smallest
B' at which the kernel path's modeled throughput beats the jnp path (small
batches are launch-overhead bound; large batches amortize the fused
pipeline's VMEM working set).  Run:

    PYTHONPATH=src python benchmarks/wcoj_bprime_sweep.py
"""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
import json
import sys

import numpy as np

SWEEP = (1024, 4096, 16384, 65536)


def _run_one(bp: int, use_kernel: bool):
    import repro.configs.wcoj as W
    from repro.configs.base import Cell
    from repro.configs import registry
    from repro.launch import dryrun as D

    W.SHAPES["triangle_static"]["batch"] = bp
    W.SHAPES["triangle_static"]["use_kernel"] = use_kernel
    cell = Cell("triangle_static", "join",
                W._build_cell(W.SHAPES["triangle_static"]))
    spec = registry.get_arch("wcoj-subgraph")
    object.__setattr__(spec, "cells",
                       {**spec.cells, "triangle_static": cell})
    rec = D.run_cell("wcoj-subgraph", "triangle_static", False,
                     verbose=False)
    rf = rec["roofline"]
    bound = max(rf["compute_s"], rf["memory_s"], rf["collective_s"])
    return rf, 512 * bp / bound


def main():
    results = {"jnp": [], "kernel": []}
    for use_kernel in (False, True):
        path = "kernel" if use_kernel else "jnp"
        for bp in SWEEP:
            rf, thru = _run_one(bp, use_kernel)
            results[path].append(
                dict(batch=bp, compute_s=rf["compute_s"],
                     memory_s=rf["memory_s"],
                     collective_s=rf["collective_s"],
                     dominant=rf["dominant"], proposals_per_sec=thru))
            print(f"[{path:6s}] B'={bp:6d}: "
                  f"compute {rf['compute_s']*1e3:.3f}ms "
                  f"mem {rf['memory_s']*1e3:.3f}ms "
                  f"coll {rf['collective_s']*1e3:.3f}ms -> "
                  f"{thru/1e9:.2f}G proposals/s "
                  f"(dominant {rf['dominant']})", flush=True)

    # crossover: smallest B' where the kernel path wins
    crossover = None
    for j, k in zip(results["jnp"], results["kernel"]):
        if k["proposals_per_sec"] > j["proposals_per_sec"]:
            crossover = k["batch"]
            break
    results["crossover_batch"] = crossover
    print(f"kernel-beats-jnp crossover: B'={crossover}", flush=True)

    out = os.path.join(os.path.dirname(__file__), "results",
                       "BENCH_bprime_sweep.json")
    os.makedirs(os.path.dirname(out), exist_ok=True)
    with open(out, "w") as f:
        json.dump(results, f, indent=2)
    return results


if __name__ == "__main__":
    main()
