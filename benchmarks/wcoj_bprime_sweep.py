"""Hillclimb cell #3 (wcoj triangle_static): B' sweep on the production
mesh.  The join's per-round roofline terms are fixed costs amortized over
w*B' proposals; throughput = w*B' / max(term).  Run:

    PYTHONPATH=src python benchmarks/wcoj_bprime_sweep.py
"""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
import json
import sys

import numpy as np


def main():
    import repro.configs.wcoj as W
    from repro.launch import dryrun as D

    results = []
    for bp in (1024, 4096, 16384, 65536):
        W.SHAPES["triangle_static"]["batch"] = bp
        # rebuild the cell with the new batch
        from repro.configs.base import Cell
        cell = Cell("triangle_static", "join",
                    W._build_cell(W.SHAPES["triangle_static"]))
        from repro.configs import registry
        spec = registry.get_arch("wcoj-subgraph")
        object.__setattr__(spec, "cells",
                           {**spec.cells, "triangle_static": cell})
        rec = D.run_cell("wcoj-subgraph", "triangle_static", False,
                         verbose=False)
        rf = rec["roofline"]
        bound = max(rf["compute_s"], rf["memory_s"], rf["collective_s"])
        thru = 512 * bp / bound
        results.append((bp, rf, thru))
        print(f"B'={bp:6d}: compute {rf['compute_s']*1e3:.3f}ms "
              f"mem {rf['memory_s']*1e3:.3f}ms "
              f"coll {rf['collective_s']*1e3:.3f}ms -> "
              f"{thru/1e9:.2f}G proposals/s "
              f"(dominant {rf['dominant']})", flush=True)
    return results


if __name__ == "__main__":
    main()
