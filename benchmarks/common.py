"""Shared benchmark helpers.  Every benchmark prints CSV rows:
``table,name,us_per_call,derived`` (derived = the paper-figure quantity)."""
import time


def timeit(fn, *args, repeat=3, **kw):
    """Median wall time of fn (first call excluded when it jit-compiles)."""
    fn(*args, **kw)  # warm
    times = []
    for _ in range(repeat):
        t0 = time.time()
        out = fn(*args, **kw)
        times.append(time.time() - t0)
    times.sort()
    return times[len(times) // 2], out


def row(table, name, seconds, derived=""):
    print(f"{table},{name},{seconds * 1e6:.0f},{derived}", flush=True)
