"""Composite-key (2-word) kernel crossover sweep -> BENCH_composite_sweep.json.

The PR-10 question, measured: at what probe-batch size B' does the
composite-key Pallas path beat the pure-jnp fixed-depth searches, per
kernel family (interpret mode off-TPU; numbers are comparable per-host):

  member  — composite (hi, lo, val) membership probes: the 3-word-lex
            two-level kernel vs ``csr.index_member``, B' sweep, for both
            the narrow int32-hi (3-col) and the int64-pair (4-col) layout.
  rank    — composite merge ranks (lt, le): the rank kernel vs the jnp
            double search that drives every sorted-merge fold.
  fold    — the per-relation commit fold: ONE fused pallas_call
            (kernels/merge/fold.py) vs the five-stage jitted jnp chain,
            per delta size — the per-epoch latency the serving path pays.

Each family records the crossover: the smallest swept size at which the
kernel path's throughput >= the jnp path's (None when the kernel never
wins on this host — the JSON keeps the full curves either way).

Run via ``python -m benchmarks.run --only composite_sweep`` (or directly).
"""
import json
import os

import numpy as np

from benchmarks.common import row, timeit

OUT_PATH = os.path.join(os.path.dirname(__file__), "results",
                        "BENCH_composite_sweep.json")

SWEEP_B = (256, 1024, 4096, 16384)
SWEEP_DELTA = (64, 256, 1024)
INDEX_N = 1 << 14


def _composite_index(rng, n, nk, capacity=None):
    from repro.core import csr
    rows = rng.integers(0, 1 << 10, (n, nk + 1)).astype(np.int32)
    return csr.build_index(rows, tuple(range(nk)), nk, capacity=capacity)


def _probes(rng, B, nk):
    import jax.numpy as jnp
    from repro.core import csr
    rows = rng.integers(0, 1 << 10, (B, nk + 1)).astype(np.int32)
    qh, ql = csr.pack_key(tuple(rows[:, i] for i in range(nk)))
    return jnp.asarray(qh), jnp.asarray(ql), jnp.asarray(rows[:, nk])


def _crossover(curve):
    for pt in curve:
        if pt["kernel_qps"] >= pt["jnp_qps"]:
            return pt["batch"]
    return None


def _bench_member(rec):
    from repro.core import csr
    from repro.kernels.intersect.ops import member as member_kernel
    rec["member"] = {}
    for nk in (3, 4):
        rng = np.random.default_rng(nk)
        idx = _composite_index(rng, INDEX_N, nk)
        curve = []
        for B in SWEEP_B:
            qh, ql, qv = _probes(rng, B, nk)
            t_j, m_j = timeit(lambda: np.asarray(
                csr.index_member(idx, (qh, ql), qv)))
            t_k, m_k = timeit(lambda: np.asarray(
                member_kernel(idx.key, idx.val, idx.n, qh, qv,
                              los=idx.lo, ql=ql)))
            assert (m_j == m_k).all(), "composite member parity"
            curve.append({"batch": B, "jnp_qps": B / t_j,
                          "kernel_qps": B / t_k})
        bp = _crossover(curve)
        rec["member"][f"nk{nk}"] = {
            "index_entries": int(idx.n), "hi_dtype": str(idx.key.dtype),
            "curve": curve, "crossover_batch": bp}
        row("composite_sweep", f"member_nk{nk}", 0.0,
            f"B'={bp} ({'never' if bp is None else 'kernel wins'})")


def _bench_rank(rec):
    from repro.kernels.merge.merge import rank_counts
    from repro.kernels.merge.ref import rank_ref
    rec["rank"] = {}
    for nk in (3, 4):
        rng = np.random.default_rng(10 + nk)
        idx = _composite_index(rng, INDEX_N, nk)
        curve = []
        for B in SWEEP_B:
            qh, ql, qv = _probes(rng, B, nk)
            t_j, rj = timeit(lambda: tuple(np.asarray(x) for x in rank_ref(
                idx.key, idx.val, idx.n, qh, qv, lo=idx.lo, qlo=ql)))
            t_k, rk = timeit(lambda: tuple(np.asarray(x) for x in
                             rank_counts(idx.key, idx.val, idx.n, qh, qv,
                                         interpret=True, lo=idx.lo,
                                         qlo=ql)))
            assert all((a == b).all() for a, b in zip(rj, rk))
            curve.append({"batch": B, "jnp_qps": B / t_j,
                          "kernel_qps": B / t_k})
        bp = _crossover(curve)
        rec["rank"][f"nk{nk}"] = {"curve": curve, "crossover_batch": bp}
        row("composite_sweep", f"rank_nk{nk}", 0.0, f"B'={bp}")


def _bench_fold(rec):
    from repro.core import delta as D
    rec["fold"] = {}
    for nk in (3, 4):
        rng = np.random.default_rng(20 + nk)
        rows = np.unique(rng.integers(0, 1 << 8, (2048, nk)
                                      ).astype(np.int32), axis=0)
        ba = D._packed_index(rows, 0, nk, capacity=4096)
        curve = []
        for nd in SWEEP_DELTA:
            def deltas():
                d = np.unique(rng.integers(0, 1 << 8, (nd, nk)
                                           ).astype(np.int32), axis=0)
                return D._packed_index(d, 0, nk, capacity=max(nd, 64))
            ci, cd, ui, ud = deltas(), deltas(), deltas(), deltas()
            cap = 8192

            def run(use_kernel):
                # the undonated variant: timeit re-runs on the same buffers
                out = D._commit_fold_safe(ba, ci, cd, ui, ud, cins_cap=cap,
                                          cdel_cap=cap, sharded=False,
                                          use_kernel=use_kernel)
                return tuple(int(np.asarray(x.n)) for x in out)

            t_j, nj = timeit(lambda: run(False))
            t_k, nk_ = timeit(lambda: run(True))
            assert nj == nk_, "fold parity"
            curve.append({"delta": nd, "jnp_ms": t_j * 1e3,
                          "kernel_ms": t_k * 1e3,
                          "jnp_qps": nd / t_j, "kernel_qps": nd / t_k,
                          "batch": nd})
        bp = _crossover(curve)
        rec["fold"][f"nk{nk}"] = {"base_entries": int(rows.shape[0]),
                                  "curve": curve, "crossover_delta": bp}
        row("composite_sweep", f"fold_nk{nk}", 0.0,
            f"delta'={bp} "
            f"jnp={curve[0]['jnp_ms']:.2f}ms "
            f"kernel={curve[0]['kernel_ms']:.2f}ms @{SWEEP_DELTA[0]}")


def main():
    import jax
    rec = {"bench": "composite_sweep",
           "backend": jax.default_backend(),
           "interpret_mode": jax.default_backend() != "tpu",
           "index_entries": INDEX_N}
    _bench_member(rec)
    _bench_rank(rec)
    _bench_fold(rec)
    os.makedirs(os.path.dirname(OUT_PATH), exist_ok=True)
    with open(OUT_PATH, "w") as f:
        json.dump(rec, f, indent=2)
    row("composite_sweep", "json", 0.0, OUT_PATH)


if __name__ == "__main__":
    main()
