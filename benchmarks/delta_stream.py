"""Streaming Delta-BiGJoin throughput -> BENCH_delta_stream.json.

Drives the distributed maintenance engine through a subprocess per worker
count (the XLA host-device override must be set before jax initializes), so
one invocation measures:

  w=1 / w=4   — DistDeltaBigJoin epochs/sec + updates/sec on a 1- and
                4-worker CPU mesh, every epoch ALSO differentially checked
                against delta_oracle (throughput numbers are only kept if
                the signed outputs were bit-exact);
  local       — host-local DeltaBigJoin baseline on the same stream.

Per-epoch wall times land in the JSON so successive PRs can diff the warm
steady state (first epochs pay jit compilation of the per-plan programs).

Run via ``python -m benchmarks.run --only delta_stream`` (or directly).
"""
import json
import os
import subprocess
import sys

from benchmarks.common import row

OUT_PATH = os.path.join(os.path.dirname(__file__), "results",
                        "BENCH_delta_stream.json")
SRC = os.path.join(os.path.dirname(__file__), "..", "src")

ARGS = ["--query", "triangle", "--nv", "80", "--ne", "800",
        "--batches", "10", "--batch-size", "64", "--batch", "512"]


def _run(extra):
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run(
        [sys.executable, "-m", "repro.core._delta_dist_check", *ARGS,
         *extra], capture_output=True, text=True, timeout=1800, env=env)
    if out.returncode != 0:
        raise RuntimeError(
            f"delta stream check failed: {out.stdout}\n{out.stderr}")
    return json.loads(out.stdout.strip().splitlines()[-1])


def main():
    rec = {"bench": "delta_stream", "args": ARGS}
    for name, extra in (
            ("w1", ["--workers", "1"]),
            ("w4", ["--workers", "4"]),
            ("local", ["--workers", "1", "--local"])):
        r = _run(extra)
        assert r["all_exact"], f"{name}: differential check failed"
        warm = [e for e in r["epochs"][2:]] or r["epochs"]
        t = sum(e["elapsed_s"] for e in warm)
        ups = sum(e["updates"] for e in warm) / max(t, 1e-9)
        chg = sum(e["changes"] for e in warm) / max(t, 1e-9)
        rec[name] = {
            "workers": r["workers"], "mode": r["mode"],
            "all_exact": r["all_exact"],
            "shard_entries": r["shard_entries"],
            "warm_epochs_per_s": r["warm_epochs_per_s"],
            "warm_updates_per_s": round(ups, 1),
            "warm_changes_per_s": round(chg, 1),
            "epochs": r["epochs"],
        }
        row("delta_stream", name, t / max(len(warm), 1),
            f"{ups:.0f} upd/s exact={r['all_exact']}")
    os.makedirs(os.path.dirname(OUT_PATH), exist_ok=True)
    with open(OUT_PATH, "w") as f:
        json.dump(rec, f, indent=2)
    row("delta_stream", "json", 0.0, OUT_PATH)


if __name__ == "__main__":
    main()
