"""Table 5 (SEED comparison): vanilla vs +SYM vs +SYM+TR (+factorization).

Shows the engine accommodates the literature's optimizations: symmetry
breaking (degree relabel + filters), triangle indexing (ternary relation),
and factorized evaluation for the house query."""
import time

import numpy as np

from benchmarks.common import row, timeit
from repro.core import query as Q
from repro.core.bigjoin import (BigJoinConfig, build_indices, run_bigjoin,
                                seed_tuples_for)
from repro.core.csr import Graph
from repro.core.generic_join import generic_join
from repro.core.optimizations import (build_triangle_relation,
                                      factorized_house_count,
                                      four_clique_via_tri, symmetry_break)
from repro.core.plan import make_plan
from repro.data.synthetic import rmat_graph


def _bigjoin_count(q, rels, batch=8192):
    plan = make_plan(q)
    idx = build_indices(plan, rels)
    cfg = BigJoinConfig(batch=batch, seed_chunk=batch, mode="count")
    res = run_bigjoin(plan, idx, seed_tuples_for(plan, rels), cfg=cfg)
    return res


def main(scale=10, edge_factor=8):
    raw = Graph.from_edges(rmat_graph(scale, edge_factor, 3))
    und = raw.undirected()
    sym = symmetry_break(raw)

    # 4-clique: vanilla (directed, all orientations) vs SYM vs SYM+TR
    t_van, res = timeit(lambda: _bigjoin_count(
        Q.four_clique(), {Q.EDGE: und.edges}), repeat=1)
    row("tab5_optimizations", "4clique_vanilla", t_van,
        f"count={res.count};proposals={res.proposals}")

    t_sym, res_s = timeit(lambda: _bigjoin_count(
        Q.four_clique(symmetric=True), {Q.EDGE: sym.edges}), repeat=1)
    assert res.count == 24 * res_s.count
    row("tab5_optimizations", "4clique_SYM", t_sym,
        f"count={res_s.count};proposals={res_s.proposals};"
        f"speedup={t_van / max(t_sym, 1e-9):.1f}x")

    def sym_tr():
        cnt, _ = four_clique_via_tri(sym)
        return cnt
    t_tr, cnt_tr = timeit(sym_tr, repeat=1)
    assert cnt_tr == res_s.count
    row("tab5_optimizations", "4clique_SYM_TR", t_tr,
        f"count={cnt_tr};speedup={t_van / max(t_tr, 1e-9):.1f}x")

    # house: flat SYM vs factorized
    t_flat, flat = timeit(lambda: generic_join(
        Q.house(symmetric=True), {Q.EDGE: sym.edges},
        enumerate_results=False)[1], repeat=1)
    row("tab5_optimizations", "house_SYM_flat", t_flat, f"count={flat}")
    t_fact, fact = timeit(lambda: factorized_house_count(sym), repeat=1)
    assert fact == flat
    row("tab5_optimizations", "house_factorized", t_fact,
        f"count={fact};speedup={t_flat / max(t_fact, 1e-9):.1f}x")


if __name__ == "__main__":
    main()
