"""Quickstart: count and enumerate triangles through the GraphSession facade.

    PYTHONPATH=src python examples/quickstart.py [--scale 11]

A session owns the graph (and every index built over it); queries register
against the session — by name, or as a textual pattern — and evaluate with
the worst-case-optimal BiGJoin dataflow.
"""
import argparse

import numpy as np

from repro.api import GraphSession, oracle_count
from repro.data.synthetic import rmat_graph


def main(scale=11, edge_factor=8):
    # a skewed power-law graph — the regime the paper targets
    edges = rmat_graph(scale=scale, edge_factor=edge_factor, seed=0)
    session = GraphSession(edges, local=True)
    print(f"graph: {session.num_edges:,} edges, "
          f"max out-degree {np.bincount(session.edges[:, 0]).max():,}")

    # triangles, registered by name (capacities auto-sized via AGM bounds)
    tri = session.register("triangle")
    count = tri.count()
    tuples, weights = tri.enumerate()
    print(f"BiGJoin: {count:,} triangles; first 3: "
          f"{tuples[:3].tolist()}")

    # the same motif written as a pattern — the DSL parses to the same query
    tri2 = session.register("tri2(a, b, c) := e(a, b), e(a, c), e(b, c)")
    assert tri2.count() == count

    # cross-check against the serial Generic Join oracle
    ref = oracle_count("triangle", session.edges)
    assert count == int(weights.sum()) == ref, (count, ref)
    print(f"matches serial GJ oracle ({ref:,}) ✓")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=11)
    ap.add_argument("--edge-factor", type=int, default=8)
    a = ap.parse_args()
    main(a.scale, a.edge_factor)
