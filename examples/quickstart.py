"""Quickstart: count and enumerate triangles with the BiGJoin dataflow.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import query as Q
from repro.core.bigjoin import (BigJoinConfig, build_indices, run_bigjoin,
                                seed_tuples_for)
from repro.core.csr import Graph
from repro.core.generic_join import generic_join
from repro.core.plan import make_plan
from repro.data.synthetic import rmat_graph


def main():
    # a skewed power-law graph — the regime the paper targets
    g = Graph.from_edges(rmat_graph(scale=11, edge_factor=8, seed=0))
    print(f"graph: {g.num_vertices:,} vertices, {g.num_edges:,} edges, "
          f"max out-degree {np.bincount(g.edges[:, 0]).max():,}")

    # triangles via the worst-case-optimal dataflow
    q = Q.triangle()
    plan = make_plan(q)  # count-min -> propose -> intersect levels
    print(f"attribute order: {plan.attr_order}; "
          f"{len(plan.levels)} extension level(s)")

    idx = build_indices(plan, {Q.EDGE: g.edges})
    cfg = BigJoinConfig(batch=4096, seed_chunk=4096, mode="collect",
                        out_capacity=1 << 22)
    res = run_bigjoin(plan, idx, seed_tuples_for(plan, {Q.EDGE: g.edges}),
                      cfg=cfg)
    print(f"BiGJoin: {res.count:,} triangles in {res.steps} rounds "
          f"({res.proposals:,} proposals, {res.intersections:,} "
          f"intersections)")
    print(f"first 3: {res.tuples[:3].tolist()}")

    # cross-check against the serial Generic Join oracle
    _, ref = generic_join(q, {Q.EDGE: g.edges}, enumerate_results=False)
    assert res.count == ref, (res.count, ref)
    print(f"matches serial GJ oracle ({ref:,}) ✓")


if __name__ == "__main__":
    main()
