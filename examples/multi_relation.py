"""Multi-relation sessions: the ternary ``tri`` relation feeding
4-clique-tri (§5.4), verified against the edge-only 4-clique.

The paper's closing claim is that Delta-BiGJoin generalizes from subgraph
monitoring to maintaining relational equi-joins over arbitrary dynamic
relations.  This driver exercises exactly the §5.4 workload: ONE
:class:`repro.api.GraphSession` owns TWO dynamic relations — the binary
``edge`` stream and a materialized ternary ``tri`` relation — and serves
three standing queries off the same store:

    triangle       tri(a,b,c)   := e(a,b), e(a,c), e(b,c)   (the feeder)
    4-clique       6 edge atoms                              (the reference)
    4-clique-tri   4clq := tri(a,b,c), tri(a,b,d), tri(a,c,d)

Each logical epoch is two session updates: the edge batch first, then the
triangle query's signed output delta applied to the ``tri`` relation.  The
4-clique-tri deltas must match the edge-only 4-clique deltas BIT-EXACTLY,
every epoch — the two plans walk completely different index projections
(ternary composite-key regions vs binary regions), so agreement is a real
end-to-end check of the n-ary engine.

    PYTHONPATH=src python examples/multi_relation.py          # mesh
    PYTHONPATH=src python examples/multi_relation.py --local  # 1-host

(Off-TPU, run under XLA_FLAGS=--xla_force_host_platform_device_count=4 to
get a real multi-worker mesh on CPU.)
"""
import argparse
import time

import numpy as np

from repro.api import GraphSession, canon_signed as _canon, oracle_count
from repro.data.synthetic import EdgeUpdateStream, rmat_graph


def main(scale=9, edge_factor=6, epochs=6, batch_size=128, local=False):
    edges = rmat_graph(scale, edge_factor, seed=11)
    session = GraphSession(edges, local=local, update_batch=batch_size)
    tri = session.register("triangle")
    c4 = session.register("4-clique")
    tri0, _ = tri.enumerate()  # materialize the initial tri relation
    session.add_relation("tri", tri0)
    c4t = session.register("4-clique-tri")
    backend = "host-local session" if session.local else \
        f"{session.w}-worker mesh session"
    print(f"{backend}: {session.num_edges:,} edges + "
          f"{session.num_tuples('tri'):,} tri tuples; "
          f"static 4-clique = {c4.count():,}, 4-clique-tri = "
          f"{c4t.count():,}")
    assert c4t.count() == c4.count()

    stream = EdgeUpdateStream(1 << scale, batch_size, seed=12)
    live = session.edges
    for step in range(epochs):
        upd, wts = stream.batch_at(step, live=live)
        t0 = time.time()
        r1 = session.update(upd, wts)            # edge epoch
        td = r1.deltas["triangle"]
        t_upd = td.tuples if td.tuples is not None else \
            np.zeros((0, 3), np.int32)
        t_w = td.weights if td.weights is not None else \
            np.zeros(0, np.int32)
        r2 = session.update({"tri": (t_upd, t_w)})  # tri epoch
        dt = max(time.time() - t0, 1e-9)
        live = r1.advance(live)
        a, b = r1.deltas["4-clique"], r2.deltas["4-clique-tri"]
        assert _canon(b.tuples, b.weights) == _canon(a.tuples, a.weights), \
            f"epoch {step}: tri-plan and edge-plan deltas diverged"
        print(f"  epoch {step}: triangle {td.count_delta:+,}  "
              f"4-clique {a.count_delta:+,}  4-clique-tri "
              f"{b.count_delta:+,}  (bit-exact ✓) in {dt*1e3:.0f} ms")

    # the maintained totals survive full recomputation
    ref = oracle_count("4-clique", session.edges)
    ref0 = oracle_count("4-clique", edges)
    assert c4.net_change == c4t.net_change == ref - ref0
    assert c4t.count() == c4.count() == ref
    print(f"verified: both plans net {c4.net_change:+,}, recompute diff "
          f"{ref - ref0:+,}, {ref:,} 4-cliques now ✓")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=9)
    ap.add_argument("--edge-factor", type=int, default=6)
    ap.add_argument("--epochs", type=int, default=6)
    ap.add_argument("--batch-size", type=int, default=128)
    ap.add_argument("--local", action="store_true",
                    help="host-local session instead of the mesh")
    a = ap.parse_args()
    main(a.scale, a.edge_factor, a.epochs, a.batch_size, a.local)
