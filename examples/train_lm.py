"""Train a ~100M-parameter LM for a few hundred steps on CPU.

Exercises the full training substrate end-to-end: model, AdamW + cosine
schedule, deterministic restartable data pipeline, atomic checkpoints.

    PYTHONPATH=src python examples/train_lm.py --steps 200
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs.lm_family import make_train_step
from repro.data import TokenStream
from repro.models.transformer import TransformerConfig, init
from repro.optim import adamw_init, cosine_decay


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm100m")
    args = ap.parse_args()

    # ~100M params: 8L x 512d x 8H, 32k vocab (tied embeddings)
    cfg = TransformerConfig(
        "lm-100m", num_layers=8, d_model=768, n_heads=12, n_kv_heads=4,
        head_dim=64, d_ff=2048, vocab=32768,
        param_dtype=jnp.float32, act_dtype=jnp.float32, remat=False)
    params = init(jax.random.PRNGKey(0), cfg)
    n = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    print(f"model: {n / 1e6:.1f}M params")

    opt = adamw_init(params)
    sched = cosine_decay(3e-4, 20, args.steps)
    step_fn = jax.jit(make_train_step(cfg, schedule=sched),
                      donate_argnums=(0, 1))
    ts = TokenStream(cfg.vocab, args.batch, args.seq, seed=0)
    mgr = CheckpointManager(args.ckpt_dir, keep_last=2)

    t0, losses = time.time(), []
    for s in range(args.steps):
        b = ts.batch_at(s)
        batch = {"tokens": jnp.asarray(b[:, :-1]),
                 "labels": jnp.asarray(b[:, 1:])}
        params, opt, m = step_fn(params, opt, batch)
        losses.append(float(m["loss"]))
        if (s + 1) % 20 == 0:
            dt = time.time() - t0
            print(f"step {s + 1:4d} loss {losses[-1]:.4f} "
                  f"({args.batch * args.seq * 20 / dt:,.0f} tok/s)")
            t0 = time.time()
        if (s + 1) % 100 == 0:
            mgr.save({"params": params, "opt": opt}, s + 1)
    first, last = np.mean(losses[:20]), np.mean(losses[-20:])
    print(f"loss {first:.3f} -> {last:.3f} "
          f"({'improved ✓' if last < first else 'no improvement ✗'})")
    assert last < first


if __name__ == "__main__":
    main()
