"""End-to-end serving driver: continuous MULTI-query subgraph monitoring.

The paper's deployment scenario (§5.3) through the facade: one
:class:`repro.api.GraphSession` owns the graph; triangle and diamond
register as standing queries against it.  Every update epoch the session
runs ONE normalize, evaluates BOTH queries' delta pipelines off the same
shared multi-version index regions, and performs ONE commit — Delta-BiGJoin
evaluates only the delta queries, never recomputing from scratch, and the
queries do not pay per-query index copies or commits.

By default the session runs on the MESH: every local device is a dataflow
worker holding one hash-partitioned shard of every index region.
``--local`` keeps the session on the host — same bookkeeping, no mesh.

    PYTHONPATH=src python examples/incremental_motifs.py          # mesh
    PYTHONPATH=src python examples/incremental_motifs.py --local  # 1-host

(Off-TPU, run under XLA_FLAGS=--xla_force_host_platform_device_count=4 to
get a real multi-worker mesh on CPU.)
"""
import argparse
import time

import numpy as np

from repro.api import GraphSession, oracle_count
from repro.data.synthetic import rmat_graph


def main(scale=11, edge_factor=8, batches=6, batch_size=800, local=False):
    edges = rmat_graph(scale, edge_factor, seed=7)
    n0 = edges.shape[0] - batches * batch_size
    session = GraphSession(edges[:n0], local=local,
                           update_batch=batch_size + batch_size // 8)
    names = ("triangle", "diamond")
    handles = [session.register(n) for n in names]
    backend = "host-local session" if session.local else \
        f"{session.w}-worker mesh session"
    print(f"loading {session.num_edges:,} edges; monitoring "
          f"{' + '.join(names)} on ONE {backend} under {batches} update "
          f"batches of {batch_size} (single commit per epoch)")

    rng = np.random.default_rng(0)
    start = session.edges.copy()
    for i in range(batches):
        lo = n0 + i * batch_size
        ins = edges[lo:lo + batch_size]
        # delete a few random live edges too (mixed workload)
        live = session.edges
        dels = live[rng.choice(live.shape[0], size=batch_size // 8,
                               replace=False)]
        batch = np.concatenate([ins, dels])
        weights = np.concatenate([
            np.ones(len(ins), np.int32), -np.ones(len(dels), np.int32)])
        t0 = time.time()
        res = session.update(batch, weights)
        dt = max(time.time() - t0, 1e-9)
        line = [f"batch {i}:"]
        for h in handles:
            d = res.deltas[h.name]
            changes = 0 if d.weights is None else int(
                np.abs(d.weights).sum())
            line.append(f"{h.name} {d.count_delta:+,} "
                        f"({changes / dt:,.0f} changes/s)")
        print("  " + "  ".join(line))

    # verify the maintained totals against full recomputation
    st = session.stats
    assert st.commit_calls == st.normalize_calls == batches, st
    for h in handles:
        ref = oracle_count(h.query, session.edges)
        ref0 = oracle_count(h.query, start)
        assert h.net_change == ref - ref0, (h.name, h.net_change, ref - ref0)
        print(f"{h.name}: maintained total change {h.net_change:+,} == "
              f"recompute diff ✓ (now {ref:,} instances)")
    print(f"epoch accounting: {st.commit_calls} commits / "
          f"{st.normalize_calls} normalizes for {len(handles)} standing "
          "queries ✓")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=11)
    ap.add_argument("--edge-factor", type=int, default=8)
    ap.add_argument("--batches", type=int, default=6)
    ap.add_argument("--batch-size", type=int, default=800)
    ap.add_argument("--local", action="store_true",
                    help="host-local session instead of the mesh")
    a = ap.parse_args()
    main(a.scale, a.edge_factor, a.batches, a.batch_size, a.local)
