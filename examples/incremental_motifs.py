"""End-to-end serving driver: continuous subgraph-query monitoring.

This is the paper's deployment scenario (§5.3): load a large graph, then
*monitor* motif counts as edge updates stream in — Delta-BiGJoin evaluates
only the delta queries, never recomputing from scratch.  Mixed
insert/delete batches exercise the multi-version LSM index.

    PYTHONPATH=src python examples/incremental_motifs.py
"""
import time

import numpy as np

from repro.core import query as Q
from repro.core.bigjoin import BigJoinConfig
from repro.core.delta import DeltaBigJoin
from repro.core.csr import Graph
from repro.data.synthetic import rmat_graph


def main(scale=11, edge_factor=8, batches=6, batch_size=800):
    g = Graph.from_edges(rmat_graph(scale, edge_factor, seed=7))
    n0 = g.num_edges - batches * batch_size
    print(f"loading {n0:,} edges; monitoring triangle + diamond under "
          f"{batches} update batches of {batch_size}")

    monitors = {
        name: DeltaBigJoin(Q.PAPER_QUERIES[name](), g.edges[:n0],
                           cfg=BigJoinConfig(batch=8192, seed_chunk=8192,
                                             mode="collect",
                                             out_capacity=1 << 22))
        for name in ("triangle", "diamond")
    }
    totals = {name: 0 for name in monitors}
    rng = np.random.default_rng(0)
    live = g.edges[:n0].copy()

    for i in range(batches):
        lo = n0 + i * batch_size
        ins = g.edges[lo:lo + batch_size]
        # delete a few random live edges too (mixed workload)
        dels = live[rng.choice(live.shape[0], size=batch_size // 8,
                               replace=False)]
        batch = np.concatenate([ins, dels])
        weights = np.concatenate([
            np.ones(len(ins), np.int32), -np.ones(len(dels), np.int32)])
        line = [f"batch {i}:"]
        for name, eng in monitors.items():
            t0 = time.time()
            res = eng.apply(batch, weights)
            dt = time.time() - t0
            totals[name] += res.count_delta
            changes = 0 if res.weights is None else int(
                np.abs(res.weights).sum())
            line.append(f"{name} {res.count_delta:+,} "
                        f"({changes / dt:,.0f} changes/s)")
        print("  " + "  ".join(line))
        live = monitors["triangle"].edges  # engine tracks the live set

    # verify the maintained totals against full recomputation
    from repro.core.generic_join import generic_join
    for name, eng in monitors.items():
        _, ref = generic_join(Q.PAPER_QUERIES[name](), {Q.EDGE: live},
                              enumerate_results=False)
        _, ref0 = generic_join(Q.PAPER_QUERIES[name](),
                               {Q.EDGE: g.edges[:n0]},
                               enumerate_results=False)
        assert totals[name] == ref - ref0, (name, totals[name], ref - ref0)
        print(f"{name}: maintained total change {totals[name]:+,} == "
              f"recompute diff ✓ (now {ref:,} instances)")


if __name__ == "__main__":
    main()
