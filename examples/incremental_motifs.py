"""End-to-end serving driver: continuous subgraph-query monitoring.

This is the paper's deployment scenario (§5.3): load a large graph, then
*monitor* motif counts as edge updates stream in — Delta-BiGJoin evaluates
only the delta queries, never recomputing from scratch.  Mixed
insert/delete batches exercise the multi-version LSM index.

By default the monitors run on the MESH: every local device is a dataflow
worker holding one hash-partitioned shard of every index region
(``DistDeltaBigJoin``).  ``--local`` uses the host-local engine instead —
same host bookkeeping, no mesh.

    PYTHONPATH=src python examples/incremental_motifs.py          # mesh
    PYTHONPATH=src python examples/incremental_motifs.py --local  # 1-host

(Off-TPU, run under XLA_FLAGS=--xla_force_host_platform_device_count=4 to
get a real multi-worker mesh on CPU.)
"""
import argparse
import time

import numpy as np

from repro.core import query as Q
from repro.core.csr import Graph
from repro.data.synthetic import rmat_graph


def make_monitor(name, edges, local, bprime=8192):
    from repro.core.distributed import make_delta_monitor
    return make_delta_monitor(Q.PAPER_QUERIES[name](), edges, local=local,
                              batch=bprime, out_capacity=1 << 22)


def main(scale=11, edge_factor=8, batches=6, batch_size=800, local=False):
    g = Graph.from_edges(rmat_graph(scale, edge_factor, seed=7))
    n0 = g.num_edges - batches * batch_size
    backend = "host-local engine" if local else "mesh-backed engine"
    print(f"loading {n0:,} edges; monitoring triangle + diamond on the "
          f"{backend} under {batches} update batches of {batch_size}")

    monitors = {name: make_monitor(name, g.edges[:n0], local)
                for name in ("triangle", "diamond")}
    totals = {name: 0 for name in monitors}
    rng = np.random.default_rng(0)
    live = g.edges[:n0].copy()

    for i in range(batches):
        lo = n0 + i * batch_size
        ins = g.edges[lo:lo + batch_size]
        # delete a few random live edges too (mixed workload)
        dels = live[rng.choice(live.shape[0], size=batch_size // 8,
                               replace=False)]
        batch = np.concatenate([ins, dels])
        weights = np.concatenate([
            np.ones(len(ins), np.int32), -np.ones(len(dels), np.int32)])
        line = [f"batch {i}:"]
        for name, eng in monitors.items():
            t0 = time.time()
            res = eng.apply(batch, weights)
            dt = max(time.time() - t0, 1e-9)
            totals[name] += res.count_delta
            changes = 0 if res.weights is None else int(
                np.abs(res.weights).sum())
            line.append(f"{name} {res.count_delta:+,} "
                        f"({changes / dt:,.0f} changes/s)")
        print("  " + "  ".join(line))
        live = monitors["triangle"].edges  # engine tracks the live set

    # verify the maintained totals against full recomputation
    from repro.core.generic_join import generic_join
    for name, eng in monitors.items():
        _, ref = generic_join(Q.PAPER_QUERIES[name](), {Q.EDGE: live},
                              enumerate_results=False)
        _, ref0 = generic_join(Q.PAPER_QUERIES[name](),
                               {Q.EDGE: g.edges[:n0]},
                               enumerate_results=False)
        assert totals[name] == ref - ref0, (name, totals[name], ref - ref0)
        print(f"{name}: maintained total change {totals[name]:+,} == "
              f"recompute diff ✓ (now {ref:,} instances)")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=11)
    ap.add_argument("--edge-factor", type=int, default=8)
    ap.add_argument("--batches", type=int, default=6)
    ap.add_argument("--batch-size", type=int, default=800)
    ap.add_argument("--local", action="store_true",
                    help="host-local DeltaBigJoin instead of the mesh")
    a = ap.parse_args()
    main(a.scale, a.edge_factor, a.batches, a.batch_size, a.local)
