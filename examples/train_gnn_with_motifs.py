"""GNN training with WCOJ motif features: the paper's engine as a
first-class data-pipeline stage (DESIGN.md §4).

Task: predict whether a vertex participates in an above-median number of
triangles, from local features.  A GatedGCN *with* BiGJoin-computed motif
features solves this much better than one without — demonstrating the
join engine feeding the learning stack.

    PYTHONPATH=src python examples/train_gnn_with_motifs.py
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.gnn_family import make_train_step
from repro.core.csr import Graph
from repro.data.motifs import motif_features
from repro.data.synthetic import rmat_graph
from repro.models import gnn as G
from repro.optim import adamw_init


def run(with_motifs: bool, graph, feats_rand, labels, steps=60):
    feats = feats_rand
    if with_motifs:
        motifs = motif_features(graph, ("triangle",))
        feats = np.concatenate([feats_rand, motifs], 1)
    cfg = G.GNNConfig("demo", "gatedgcn", n_layers=3, d_hidden=32,
                      d_in=feats.shape[1], d_out=2, task="node_class")
    params = G.init(jax.random.PRNGKey(0), cfg)
    opt = adamw_init(params)
    step_fn = jax.jit(make_train_step(cfg), donate_argnums=(0, 1))
    e = graph.edges
    batch = {
        "feats": jnp.asarray(feats),
        "edge_src": jnp.asarray(e[:, 0]), "edge_dst": jnp.asarray(e[:, 1]),
        "edge_mask": jnp.ones(e.shape[0], bool),
        "edge_feats": jnp.ones((e.shape[0], 1), jnp.float32),
        "labels": jnp.asarray(labels),
        "label_mask": jnp.ones(labels.shape[0], bool),
    }
    for _ in range(steps):
        params, opt, m = step_fn(params, opt, batch)
    return float(m["acc"])


def main():
    graph = Graph.from_edges(rmat_graph(10, 8, seed=1))
    rng = np.random.default_rng(0)
    feats_rand = rng.normal(size=(graph.num_vertices, 8)).astype(np.float32)
    tri = motif_features(graph, ("triangle",))[:, 0]
    labels = (tri > np.median(tri)).astype(np.int32)

    acc_plain = run(False, graph, feats_rand, labels)
    acc_motif = run(True, graph, feats_rand, labels)
    print(f"accuracy without motif features: {acc_plain:.3f}")
    print(f"accuracy with  WCOJ motif features: {acc_motif:.3f}")
    assert acc_motif > acc_plain + 0.1, "motif features should dominate"
    print("WCOJ features lift accuracy ✓")


if __name__ == "__main__":
    main()
